"""End-to-end serving driver: batched FAVOR engine under a mixed workload.

Simulates the paper's production scenario: a stream of hybrid queries with
heterogeneous filters (and thus heterogeneous selectivity) hits the batched
engine; the selectivity-driven selector routes each to PreFBF or the
exclusion-distance graph search.  Reports routing statistics, recall and
latency percentiles.

One unmodified ServeEngine drives any execution backend: here the single-host
LocalBackend and (on the same device inventory) the sharded serve path via
ShardedBackend -- run with XLA_FLAGS=--xla_force_host_platform_device_count=S
to actually spread the DB over S shards.

    PYTHONPATH=src python examples/serve_anns.py
"""
import numpy as np

import jax

from repro.core import (BuildSpec, FavorIndex, HnswParams, LocalBackend,
                        SearchOptions, ShardedBackend, paper_filters)
from repro.core import filters as F
from repro.core import refimpl
from repro.data import synthetic
from repro.serving import ServeEngine


def drive(eng, workload, dim, n_requests=512, seed=0):
    rng = np.random.default_rng(seed)
    reqs = {}
    for i in range(n_requests):
        q = synthetic.make_queries(1, dim, seed=200 + i)[0]
        flt = workload[int(rng.integers(0, len(workload)))]
        rid = eng.submit(q, flt)
        reqs[rid] = (q, flt)
    responses = eng.run()
    return responses, reqs


def report(tag, eng, responses, reqs, vecs, attrs, schema, seed=0):
    print(f"[{tag}] done: {len(responses)} responses in "
          f"{eng.stats['batches']} batches")
    print(f"[{tag}] routing: graph={eng.stats['graph']} "
          f"brute={eng.stats['brute']}")
    pct = eng.latency_percentiles()
    print(f"[{tag}] latency ms: "
          + "  ".join(f"{k}={v:.1f}" for k, v in pct.items()))

    rng = np.random.default_rng(seed)
    sample = rng.choice(len(responses), 32, replace=False)
    recs = []
    for si in sample:
        r = responses[si]
        q, flt = reqs[r.rid]
        mask = F.eval_program(F.compile_filter(flt, schema), attrs.ints,
                              attrs.floats)
        truth, _ = refimpl.bruteforce_filtered(vecs, mask, q, 10)
        recs.append(refimpl.recall_at_k(r.ids[r.ids >= 0], truth, 10))
    print(f"[{tag}] sampled recall@10 = {np.mean(recs):.3f}")


def main():
    n, dim = 10000, 32
    print(f"building index ({n} x {dim}) ...")
    vecs, attrs, schema = synthetic.make_paper_dataset(n, dim, seed=1)
    spec = BuildSpec(hnsw=HnswParams(M=12, efc=60, seed=1))
    opts = SearchOptions(k=10, ef=96)

    rng = np.random.default_rng(0)
    base = paper_filters(schema)
    workload = list(base.values()) + [
        F.And(F.Equality("i0", int(v)), F.Range("f0", lo, lo + 8.0))  # ~0.8%
        for v, lo in zip(rng.integers(0, 10, 4), rng.uniform(0, 90, 4))
    ]
    print(f"serving 512 requests with {len(workload)} filter kinds ...")

    # -- single-host backend -------------------------------------------------
    local = LocalBackend(FavorIndex.build(vecs, attrs, spec=spec))
    eng = ServeEngine(local, opts, max_batch=64)
    responses, reqs = drive(eng, workload, dim)
    report("local", eng, responses, reqs, vecs, attrs, schema)

    # -- sharded backend (same engine, same options) -------------------------
    from repro.core.distributed import largest_divisor
    n_model = largest_divisor(n, len(jax.devices()))
    mesh = jax.make_mesh((1, n_model), ("data", "model"))
    print(f"sharding DB {n_model}-way on the model axis ...")
    shard = ShardedBackend.build(vecs, attrs, mesh, spec, seed=1)
    eng = ServeEngine(shard, opts, max_batch=64)
    responses, reqs = drive(eng, workload, dim, seed=1)
    report(f"sharded x{n_model}", eng, responses, reqs, vecs, attrs, schema,
           seed=1)


if __name__ == "__main__":
    main()
