"""FAVOR as the recsys retrieval layer (the retrieval_cand cell, reduced).

Scores a user vector against a candidate item corpus under attribute filters
(region/price/stock-style predicates), using:
  1. the factorized dot-scoring path (jnp),
  2. the FAVOR PreFBF Pallas kernel (fused filter + distance + top-k) via the
     exact MIP->L2 augmentation reduction,
  3. a FAVOR graph index over the item embeddings for sub-linear retrieval.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (FavorIndex, HnswParams, SearchOptions,
                        compile_filter, paper_schema, stack_programs)
from repro.core import filters as F
from repro.core import random_attributes
from repro.models.recsys import retrieval_topk_filtered


def main():
    n_items, d, k = 20000, 32, 50
    rng = np.random.default_rng(0)
    items = rng.normal(size=(n_items, d)).astype(np.float32)
    schema = paper_schema()        # b0 = in_stock, i0 = category, f0 = price
    attrs = random_attributes(schema, n_items, seed=1)
    users = rng.normal(size=(4, d)).astype(np.float32)

    flt = F.And(F.Equality("b0", True),          # in stock
                F.Inclusion("i0", [2, 5, 7]),    # category in {2,5,7}
                F.Range("f0", 10.0, 80.0))       # price band
    progs = {kk: jnp.asarray(v) for kk, v in stack_programs(
        [compile_filter(flt, schema)] * len(users)).items()}
    ai, af = jnp.asarray(attrs.ints), jnp.asarray(attrs.floats)
    it, uv = jnp.asarray(items), jnp.asarray(users)

    t0 = time.perf_counter()
    ids_j, sc_j = retrieval_topk_filtered(uv, it, progs, ai, af, k=k)
    ids_j.block_until_ready()
    print(f"jnp dot-scoring path:    {time.perf_counter()-t0:.3f}s "
          f"(top score {float(sc_j[0, 0]):.3f})")

    t0 = time.perf_counter()
    ids_p, sc_p = retrieval_topk_filtered(uv, it, progs, ai, af, k=k,
                                          use_pallas=True)
    ids_p.block_until_ready()
    print(f"Pallas filtered_topk:    {time.perf_counter()-t0:.3f}s "
          f"(interpret mode on CPU; identical ids: "
          f"{bool((ids_j == ids_p).all())})")

    # graph path: L2 FAVOR index over L2-normalized items (cosine retrieval)
    items_n = items / np.linalg.norm(items, axis=1, keepdims=True)
    fi = FavorIndex.build(items_n, attrs, HnswParams(M=12, efc=60, seed=2))
    users_n = users / np.linalg.norm(users, axis=1, keepdims=True)
    # at p ~= 10% the result pool must reach ~k/p neighbors: ef >> 2k
    res = fi.query(users_n, flt, SearchOptions(k=k, ef=8 * k))
    overlap = []
    # cosine ground truth under the same filter
    from repro.core import refimpl
    mask = F.eval_program(compile_filter(flt, schema), attrs.ints, attrs.floats)
    for i in range(len(users)):
        truth, _ = refimpl.bruteforce_filtered(items_n, mask, users_n[i], k)
        overlap.append(refimpl.recall_at_k(res.ids[i], truth, k))
    print(f"FAVOR graph retrieval:   recall@{k}={np.mean(overlap):.3f} "
          f"qps={res.qps:.1f} (p_hat={res.p_hat[0]:.3f}, "
          f"route={'brute' if res.routed_brute[0] else 'graph'})")


if __name__ == "__main__":
    main()
